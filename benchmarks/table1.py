"""Table 1: PerMFL vs conventional + multi-tier baselines.

Per (dataset x model-class): runs PerMFL and six baselines on identical
non-IID partitions and reports validation accuracy for PM and GM. Every
cell is a named scenario (``table1/{dataset}/{model}/{algo}`` in
`repro.scenarios.SCENARIOS`) carrying its paper reference number; quick
mode derives shrunken CNN variants via ``FLScenario.scaled``.

Each algorithm's multi-seed runs (different model inits) execute as ONE
vmapped program via sweep_scenario — the reported cell is the seed-mean
of the best metric; quick mode keeps 2 seeds per cell, --full 3.
"""
from __future__ import annotations

import time

import numpy as np

from repro.scenarios import (SCENARIOS, TABLE1_ALGOS, TABLE1_DATASETS,
                             sweep_scenario)

DATASETS = TABLE1_DATASETS

# quick-mode shrink for the CPU-heavy non-convex (CNN) cells: 2 teams x 5
# devices, K=3 (keep L=10: theta re-initializes from w every team
# iteration per Algorithm 1, so PM quality needs enough consecutive
# device steps), and fewer inner steps for the multi-step baselines
_QUICK_ALGO = {
    "permfl": {"k_team": 3},
    "fedavg": {"local_steps": 30},
    "perfedavg": {"local_steps": 5},
    "pfedme": {"inner_steps": 5, "local_rounds": 3},
    "ditto": {"local_steps": 5},
    "hsgd": {"k_team": 3},
    "l2gd": {"k_team": 3},
}


def _seed_mean_best(scenario, seeds, rounds, fields):
    """All seeds of one scenario as a single vmapped sweep; returns
    {field: mean over seeds of the best-eval value}."""
    sw = sweep_scenario(scenario, [{}], seeds, rounds=rounds)
    return {f: float(np.mean([r.best(f) for r in sw])) for f in fields}


def run_all_algorithms(dataset: str, convex: bool, rounds: int,
                       seeds=(0, 1), quick: bool = True):
    """One (dataset x model-class) row: every Table-1 scenario cell,
    multi-seeded; returns {algo_metric: seed-mean best accuracy}."""
    kind = "mclr" if convex else ("dnn" if dataset == "synthetic" else "cnn")
    small = quick and not convex and dataset != "synthetic"
    out = {}
    for algo in TABLE1_ALGOS:
        s = SCENARIOS[f"table1/{dataset}/{kind}/{algo}"]
        if small:
            s = s.scaled(m_teams=2, n_devices=5, samples_per_device=24,
                         algo_overrides=_QUICK_ALGO[algo])
        res = _seed_mean_best(s, seeds, rounds, s.algo.metrics)
        for f in s.algo.metrics:
            out[f"{algo}_{f}"] = res[f]
    return out


def main(quick: bool = True, csv=print):
    rounds_cx = 12 if quick else 60
    rounds_ncx = 5 if quick else 40
    # quick mode multi-seeds only the cheap convex cells (the CNN cells
    # dominate runtime); --full multi-seeds everything
    seeds_cx = (0, 1) if quick else (0, 1, 2)
    seeds_ncx = (0,) if quick else (0, 1, 2)
    csv("table,dataset,model,algorithm,acc,paper_acc")
    failures = []
    for convex, rounds, seeds in ((True, rounds_cx, seeds_cx),
                                  (False, rounds_ncx, seeds_ncx)):
        mdl = "mclr" if convex else "cnn/dnn"
        for ds in DATASETS:
            t0 = time.time()
            res = run_all_algorithms(ds, convex, rounds, seeds=seeds,
                                     quick=quick)
            kind = "mclr" if convex else ("dnn" if ds == "synthetic"
                                          else "cnn")
            for key, acc in sorted(res.items()):
                algo, metric = key.rsplit("_", 1)
                refs = dict(SCENARIOS[f"table1/{ds}/{kind}/{algo}"]
                            .paper_ref)
                ref = refs.get(metric, "")
                csv(f"table1,{ds},{mdl},{key},{acc:.4f},{ref}")
            # qualitative checks (the reproduction targets)
            if not res["permfl_pm"] >= res["permfl_gm"]:
                failures.append((ds, mdl, "PM < GM"))
            if not res["permfl_pm"] >= res["fedavg_gm"] - 0.02:
                failures.append((ds, mdl, "PerMFL(PM) < FedAvg(GM)"))
            csv(f"# {ds}/{mdl} done in {time.time() - t0:.0f}s "
                f"({len(seeds)} seeds/algo, vmapped)")
    for f in failures:
        csv(f"# QUALITATIVE-CHECK-FAILED: {f}")
    return failures


if __name__ == "__main__":
    main()
