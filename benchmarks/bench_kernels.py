"""Kernel microbenchmarks (CPU wall-time of the jnp reference path, plus a
correctness cross-check of the Pallas body in interpret mode).

On this CPU container the numbers measure the *reference* implementations
(the compiled-Pallas path needs a real TPU); they exist to (a) track
regressions in the oracle implementations the models actually run on CPU
and (b) assert kernel/oracle agreement inside the bench harness too."""
from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np


def _time(fn, *args, iters=5):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def bench_prox(csv=print):
    from repro.kernels.prox_update.ref import prox_sgd_ref

    for n in (1 << 16, 1 << 20):
        k = jax.random.PRNGKey(0)
        theta, g, w = (jax.random.normal(kk, (n,))
                       for kk in jax.random.split(k, 3))
        f = jax.jit(lambda t, gg, ww: prox_sgd_ref(
            t, gg, ww, alpha=0.01, lam=0.5))
        us = _time(f, theta, g, w)
        csv(f"kernels,prox_sgd,n={n},us_per_call,{us:.1f},"
            f"gbps,{4 * n * 4 / us / 1e3:.2f}")


def bench_quantize(csv=print):
    from repro.kernels.quantize.ref import quantize_int8_ref

    for n in (1 << 16, 1 << 20):
        k = jax.random.PRNGKey(5)
        v = jax.random.normal(k, (n,))
        noise = jax.random.uniform(jax.random.fold_in(k, 1), (n,))
        f = jax.jit(quantize_int8_ref)  # full (q, scales, dq) — no DCE
        us = _time(f, v, noise)
        # reads v+noise (8B/elem), writes q+dq+scales (~5B/elem)
        csv(f"kernels,quantize_int8,n={n},us_per_call,{us:.1f},"
            f"gbps,{13 * n / us / 1e3:.2f}")


def bench_attention(csv=print):
    from repro.kernels.flash_attention.ref import attention_ref

    for s in (512, 2048):
        k = jax.random.PRNGKey(1)
        q = jax.random.normal(k, (1, s, 8, 64), jnp.bfloat16)
        kv = jax.random.normal(k, (1, s, 2, 64), jnp.bfloat16)
        f = jax.jit(lambda q_, k_, v_: attention_ref(q_, k_, v_, causal=True))
        us = _time(f, q, kv, kv, iters=3)
        flops = 4 * s * s * 8 * 64 / 2  # causal
        csv(f"kernels,attention,s={s},us_per_call,{us:.0f},"
            f"gflops,{flops / us / 1e3:.1f}")


def bench_wkv(csv=print):
    from repro.kernels.rwkv6_scan.ref import wkv6_ref

    k = jax.random.PRNGKey(2)
    b, t, h, n = 1, 512, 4, 64
    ks = jax.random.split(k, 5)
    r, kk, v = (jax.random.normal(x, (b, t, h, n)) * 0.3 for x in ks[:3])
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (b, t, h, n)))
    u = jax.random.normal(ks[4], (h, n)) * 0.1
    f = jax.jit(lambda *a: wkv6_ref(*a)[0])
    us = _time(f, r, kk, v, w, u, iters=3)
    csv(f"kernels,wkv6,t={t},us_per_call,{us:.0f}")


def bench_router(csv=print):
    from repro.kernels.moe_router.ref import route_ref

    logits = jax.random.normal(jax.random.PRNGKey(3), (4096, 64))
    f = jax.jit(lambda l: route_ref(l, top_k=6)[0])
    us = _time(f, logits)
    csv(f"kernels,moe_router,t=4096xE64k6,us_per_call,{us:.1f}")


def check_interpret_agreement(csv=print):
    """Pallas kernel bodies (interpret) vs refs — the same check the test
    suite sweeps, asserted once here so bench output records it."""
    os.environ["FORCE_PALLAS_INTERPRET"] = "1"
    fails = []
    try:
        from repro.kernels.prox_update.ops import prox_sgd
        from repro.kernels.prox_update.ref import prox_sgd_ref

        k = jax.random.PRNGKey(4)
        theta, g, w = (jax.random.normal(kk, (2048,))
                       for kk in jax.random.split(k, 3))
        a, _ = prox_sgd(theta, g, w, alpha=0.01, lam=0.5)
        b, _ = prox_sgd_ref(theta, g, w, alpha=0.01, lam=0.5)
        ok = bool(jnp.allclose(a, b, atol=1e-6))
        csv(f"kernels,interpret_agreement,prox_sgd,allclose,{ok}")
        if not ok:
            fails.append("prox interpret mismatch")

        from repro.kernels.quantize.ops import quantize_int8
        from repro.kernels.quantize.ref import quantize_int8_ref

        v = jax.random.normal(k, (4096,))
        noise = jax.random.uniform(jax.random.fold_in(k, 1), (4096,))
        q_k, _, dq_k = quantize_int8(v, noise)
        q_r, _, dq_r = quantize_int8_ref(v, noise)
        ok = bool((q_k == q_r).all() and (dq_k == dq_r).all())
        csv(f"kernels,interpret_agreement,quantize_int8,exact,{ok}")
        if not ok:
            fails.append("quantize interpret mismatch")
        return fails
    finally:
        os.environ.pop("FORCE_PALLAS_INTERPRET", None)


def main(quick=True, csv=print):
    bench_prox(csv)
    bench_quantize(csv)
    bench_attention(csv)
    bench_wkv(csv)
    bench_router(csv)
    return check_interpret_agreement(csv)


if __name__ == "__main__":
    main()
