"""Kernel microbenchmarks (CPU wall-time of the jnp reference path, plus a
correctness cross-check of the Pallas body in interpret mode).

On this CPU container the numbers measure the *reference* implementations
(the compiled-Pallas path needs a real TPU); they exist to (a) track
regressions in the oracle implementations the models actually run on CPU
and (b) assert kernel/oracle agreement inside the bench harness too.

The compression section emits one Pallas-vs-XLA line per compressor
(topk / randk / int8 / sign plus their fused-EF variants) and persists
them to ``BENCH_kernels.json`` at the repo root — the kernel half of the
perf trajectory that ``BENCH_engine.json`` tracks for the engine. CI
gates the XLA rates against ``benchmarks/baselines/BENCH_kernels.json``
via ``python -m repro.obs.regress`` (the Pallas column is interpret-mode
on CPU — a correctness probe, reported but never gated)."""
from __future__ import annotations

import json
import os
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

_BENCH_JSON = pathlib.Path(__file__).resolve().parents[1] / \
    "BENCH_kernels.json"


def _time(fn, *args, iters=5):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def bench_prox(csv=print):
    from repro.kernels.prox_update.ref import prox_sgd_ref

    for n in (1 << 16, 1 << 20):
        k = jax.random.PRNGKey(0)
        theta, g, w = (jax.random.normal(kk, (n,))
                       for kk in jax.random.split(k, 3))
        f = jax.jit(lambda t, gg, ww: prox_sgd_ref(
            t, gg, ww, alpha=0.01, lam=0.5))
        us = _time(f, theta, g, w)
        csv(f"kernels,prox_sgd,n={n},us_per_call,{us:.1f},"
            f"gbps,{4 * n * 4 / us / 1e3:.2f}")


def bench_quantize(csv=print):
    from repro.kernels.quantize.ref import quantize_int8_ref

    for n in (1 << 16, 1 << 20):
        k = jax.random.PRNGKey(5)
        v = jax.random.normal(k, (n,))
        noise = jax.random.uniform(jax.random.fold_in(k, 1), (n,))
        f = jax.jit(quantize_int8_ref)  # full (q, scales, dq) — no DCE
        us = _time(f, v, noise)
        # reads v+noise (8B/elem), writes q+dq+scales (~5B/elem)
        csv(f"kernels,quantize_int8,n={n},us_per_call,{us:.1f},"
            f"gbps,{13 * n / us / 1e3:.2f}")


def bench_attention(csv=print):
    from repro.kernels.flash_attention.ref import attention_ref

    for s in (512, 2048):
        k = jax.random.PRNGKey(1)
        q = jax.random.normal(k, (1, s, 8, 64), jnp.bfloat16)
        kv = jax.random.normal(k, (1, s, 2, 64), jnp.bfloat16)
        f = jax.jit(lambda q_, k_, v_: attention_ref(q_, k_, v_, causal=True))
        us = _time(f, q, kv, kv, iters=3)
        flops = 4 * s * s * 8 * 64 / 2  # causal
        csv(f"kernels,attention,s={s},us_per_call,{us:.0f},"
            f"gflops,{flops / us / 1e3:.1f}")


def bench_wkv(csv=print):
    from repro.kernels.rwkv6_scan.ref import wkv6_ref

    k = jax.random.PRNGKey(2)
    b, t, h, n = 1, 512, 4, 64
    ks = jax.random.split(k, 5)
    r, kk, v = (jax.random.normal(x, (b, t, h, n)) * 0.3 for x in ks[:3])
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (b, t, h, n)))
    u = jax.random.normal(ks[4], (h, n)) * 0.1
    f = jax.jit(lambda *a: wkv6_ref(*a)[0])
    us = _time(f, r, kk, v, w, u, iters=3)
    csv(f"kernels,wkv6,t={t},us_per_call,{us:.0f}")


def bench_router(csv=print):
    from repro.kernels.moe_router.ref import route_ref

    logits = jax.random.normal(jax.random.PRNGKey(3), (4096, 64))
    f = jax.jit(lambda l: route_ref(l, top_k=6)[0])
    us = _time(f, logits)
    csv(f"kernels,moe_router,t=4096xE64k6,us_per_call,{us:.1f}")


def bench_compress(csv=print, quick=True):
    """Pallas-vs-XLA line per compressor: time the fused dispatch in
    ``xla`` mode (the jitted reference the CPU container actually runs)
    against the Pallas kernel body (compiled on TPU, interpret here),
    assert bit-exact agreement, and return the marker payload."""
    from repro.kernels.compress import (ef_quantize_int8, ef_randk_compress,
                                        ef_sign_compress, ef_topk_compress,
                                        randk_compress, sign_compress,
                                        topk_compress)
    from repro.kernels.interface import on_tpu

    n = 1 << 16 if quick else 1 << 20
    k = max(1, n // 10)
    key = jax.random.PRNGKey(6)
    v = jax.random.normal(jax.random.fold_in(key, 0), (n,))
    ef = 0.1 * jax.random.normal(jax.random.fold_in(key, 1), (n,))
    u = jax.random.uniform(jax.random.fold_in(key, 2), (n,))
    noise = jax.random.uniform(jax.random.fold_in(key, 3), (n,))

    ops = {
        "topk": lambda mode: topk_compress(v, k, mode=mode),
        "ef_topk": lambda mode: ef_topk_compress(v, ef, k, mode=mode),
        "randk": lambda mode: randk_compress(u, v, k, mode=mode),
        "ef_randk": lambda mode: ef_randk_compress(u, v, ef, k, mode=mode),
        "ef_int8": lambda mode: ef_quantize_int8(v, ef, noise, mode=mode),
        "sign": lambda mode: sign_compress(v, mode=mode),
        "ef_sign": lambda mode: ef_sign_compress(v, ef, mode=mode),
    }
    pallas_mode = "pallas" if on_tpu() else "interpret"

    payload, fails = {}, []
    for name, op in ops.items():
        xla_us = _time(lambda: op("xla"), iters=5)
        pallas_us = _time(lambda: op(pallas_mode), iters=3)
        out_x = jax.tree.leaves(op("xla"))
        out_p = jax.tree.leaves(op(pallas_mode))
        agree = all(bool((np.asarray(a) == np.asarray(b)).all())
                    for a, b in zip(out_x, out_p))
        if not agree:
            fails.append(f"compress {name}: {pallas_mode} != xla")
        payload[name] = {
            "n": n,
            "xla_us": round(xla_us, 1),
            "xla_meps": round(n / xla_us, 2),          # Melem/s
            "pallas_us": round(pallas_us, 1),
            "pallas_meps": round(n / pallas_us, 2),
            "pallas_mode": pallas_mode,
            "agree": agree,
        }
        csv(f"kernels,compress,{name},n={n},xla_us,{xla_us:.1f},"
            f"{pallas_mode}_us,{pallas_us:.1f},agree,{agree}")
    return payload, fails


def check_interpret_agreement(csv=print):
    """Pallas kernel bodies (interpret) vs refs — the same check the test
    suite sweeps, asserted once here so bench output records it."""
    os.environ["FORCE_PALLAS_INTERPRET"] = "1"
    fails = []
    try:
        from repro.kernels.prox_update.ops import prox_sgd
        from repro.kernels.prox_update.ref import prox_sgd_ref

        k = jax.random.PRNGKey(4)
        theta, g, w = (jax.random.normal(kk, (2048,))
                       for kk in jax.random.split(k, 3))
        a, _ = prox_sgd(theta, g, w, alpha=0.01, lam=0.5)
        b, _ = prox_sgd_ref(theta, g, w, alpha=0.01, lam=0.5)
        ok = bool(jnp.allclose(a, b, atol=1e-6))
        csv(f"kernels,interpret_agreement,prox_sgd,allclose,{ok}")
        if not ok:
            fails.append("prox interpret mismatch")

        from repro.kernels.quantize.ops import quantize_int8
        from repro.kernels.quantize.ref import quantize_int8_ref

        v = jax.random.normal(k, (4096,))
        noise = jax.random.uniform(jax.random.fold_in(k, 1), (4096,))
        q_k, _, dq_k = quantize_int8(v, noise)
        q_r, _, dq_r = quantize_int8_ref(v, noise)
        ok = bool((q_k == q_r).all() and (dq_k == dq_r).all())
        csv(f"kernels,interpret_agreement,quantize_int8,exact,{ok}")
        if not ok:
            fails.append("quantize interpret mismatch")
        return fails
    finally:
        os.environ.pop("FORCE_PALLAS_INTERPRET", None)


def write_bench_json(payload: dict) -> None:
    """Persist the kernel perf-trajectory marker at the repo root; CI
    diffs BENCH_kernels.json against benchmarks/baselines/."""
    _BENCH_JSON.write_text(json.dumps(payload, indent=2, sort_keys=True)
                           + "\n")
    print(f"# bench_kernels: wrote {_BENCH_JSON.name}")


def main(quick=True, csv=print):
    bench_prox(csv)
    bench_quantize(csv)
    bench_attention(csv)
    bench_wkv(csv)
    bench_router(csv)
    compress, fails = bench_compress(csv, quick=quick)
    fails += check_interpret_agreement(csv)
    write_bench_json({"mode": "quick" if quick else "full",
                      "compress": compress})
    return fails


if __name__ == "__main__":
    main()
