"""Thin compatibility shims over `repro.scenarios` (the real source of
truth for experiment assembly).

Historically this module hand-assembled every benchmark experiment
(datasets, models, paper tables, algorithm factories). All of that now
lives in the declarative scenario layer — `repro.scenarios.spec` builds
data/models/algorithms, `repro.scenarios.registry` names every cell, and
`repro.scenarios.paper_refs` holds the paper's numbers. The shims below
keep the historical signatures for external callers; the benchmarks
themselves construct their experiments from `SCENARIOS` /
`run_scenario` / `sweep_scenario`.

Scale notes: the paper runs 4 teams x 10 devices for 400-800 global
rounds on an A100. This container is a single CPU, so the default
("quick") scale keeps the topology but fewer rounds — enough for every
qualitative claim to reproduce; ``--full`` restores paper-scale rounds.
"""
from __future__ import annotations

# paper reference numbers (single source of truth: the scenario layer)
from repro.scenarios.paper_refs import (PAPER_TABLE1_MCLR,   # noqa: F401
                                        PAPER_TABLE1_NONCONVEX)
# experiment-assembly helpers, re-exported for compatibility
from repro.scenarios.spec import (PAPER_HP, AlgoSpec, DataSpec,  # noqa: F401
                                  ModelSpec, fns_for, init_model, to_jax)

M_TEAMS, N_DEVICES = 4, 10

# paper §4.1.4 hyperparameters (repro.scenarios.spec.PAPER_HP)
HP_DEFAULT = PAPER_HP

DATASETS = ("mnist", "fmnist", "emnist10", "synthetic")


def model_for(dataset: str, convex: bool):
    """PaperModelConfig for a (dataset, model-class) cell — shim over
    ModelSpec.config."""
    kind = "mclr" if convex else ("dnn" if dataset == "synthetic" else "cnn")
    return ModelSpec(kind).config(DataSpec(
        dataset=dataset,
        partitioner="tabular" if dataset == "synthetic" else "label_skew"))


def make_fed_data(dataset: str, seed: int = 0, *, m=M_TEAMS, n=N_DEVICES,
                  samples_per_device: int = 48, strategy: str = "random"):
    """Stacked FederatedData for a paper cell — shim over DataSpec.build."""
    return DataSpec(
        dataset=dataset,
        partitioner="tabular" if dataset == "synthetic" else "label_skew",
        m_teams=m, n_devices=n, samples_per_device=samples_per_device,
        strategy=strategy).build(seed)


def make_algorithm(name: str, loss, *, hp=HP_DEFAULT, lr: float = 0.03,
                   comm=None):
    """Paper-default FLAlgorithm instances for the unified engine, keyed
    by the Table-1 names — shim over AlgoSpec.build. lr is the baselines'
    device learning rate."""
    overrides = {
        "permfl": {k: getattr(hp, k) for k in
                   ("alpha", "eta", "beta", "lam", "gamma", "k_team",
                    "l_local", "momentum", "weight_decay")},
        "fedavg": {"lr": lr, "local_steps": hp.k_team * hp.l_local},
        "perfedavg": {"lr": lr, "inner_lr": lr},
        "pfedme": {"inner_lr": lr},
        "ditto": {"lr": lr},
        "hsgd": {"lr": lr, "k_team": hp.k_team, "l_local": hp.l_local},
        "l2gd": {"lr": lr, "k_team": hp.k_team, "l_local": hp.l_local},
    }[name]
    return AlgoSpec(name, tuple(overrides.items())).build(loss, comm=comm)
