"""Shared setup for the paper-reproduction benchmarks.

Scale notes: the paper runs 4 teams x 10 devices for 400-800 global rounds
on an A100. This container is a single CPU, so the default ("quick") scale
is 4 teams x 10 devices with fewer rounds — enough for every qualitative
claim (PM > GM orderings, convergence ranking, hyperparameter monotonicity)
to reproduce; ``--full`` restores paper-scale round counts.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.paper_cnn import CONFIG as CNN
from repro.configs.paper_dnn import CONFIG as DNN
from repro.configs.paper_mclr import CONFIG as MCLR
from repro.core import PerMFL
from repro.core import baselines as B
from repro.core.permfl import PerMFLHParams
from repro.data.federated import partition_label_skew, partition_tabular
from repro.data.synthetic import make_dataset, synthetic_tabular
from repro.models import paper_models as PM

M_TEAMS, N_DEVICES = 4, 10

# paper §4.1.4 hyperparameters
HP_DEFAULT = PerMFLHParams(alpha=0.01, eta=0.03, beta=0.6, lam=0.5,
                           gamma=1.5, k_team=5, l_local=10)

DATASETS = ("mnist", "fmnist", "emnist10", "synthetic")

# Paper Table 1 numbers (validation accuracy %) quoted for side-by-side
# qualitative comparison in EXPERIMENTS.md. {dataset: {algo: acc}}
PAPER_TABLE1_MCLR = {
    "mnist": {"fedavg_gm": 84.87, "perfedavg_pm": 94.81, "pfedme_pm": 88.89,
              "ditto_gm": 84.81, "hsgd_gm": 87.41, "al2gd_pm": 93.70,
              "permfl_gm": 86.92, "permfl_pm": 96.87},
    "synthetic": {"fedavg_gm": 79.80, "perfedavg_pm": 83.91,
                  "pfedme_pm": 87.61, "ditto_gm": 74.02, "hsgd_gm": 84.29,
                  "al2gd_pm": 84.75, "permfl_gm": 84.92, "permfl_pm": 87.94},
    "fmnist": {"fedavg_gm": 84.87, "perfedavg_pm": 94.75, "pfedme_pm": 91.23,
               "ditto_gm": 82.35, "hsgd_gm": 92.33, "al2gd_pm": 98.52,
               "permfl_gm": 83.71, "permfl_pm": 96.77},
    "emnist10": {"fedavg_gm": 91.60, "perfedavg_pm": 97.57,
                 "pfedme_pm": 91.32, "ditto_gm": 91.03, "hsgd_gm": 81.65,
                 "al2gd_pm": 98.72, "permfl_gm": 91.68, "permfl_pm": 96.49},
}
PAPER_TABLE1_NONCONVEX = {
    "mnist": {"fedavg_gm": 93.17, "perfedavg_pm": 91.85, "pfedme_pm": 97.40,
              "ditto_gm": 87.30, "hsgd_gm": 86.59, "al2gd_pm": 91.04,
              "permfl_gm": 89.39, "permfl_pm": 98.15},
    "synthetic": {"fedavg_gm": 84.53, "perfedavg_pm": 75.93,
                  "pfedme_pm": 87.86, "ditto_gm": 81.12, "hsgd_gm": 87.42,
                  "al2gd_pm": 84.92, "permfl_gm": 87.53, "permfl_pm": 87.89},
    "fmnist": {"fedavg_gm": 84.14, "perfedavg_pm": 88.69, "pfedme_pm": 96.30,
               "ditto_gm": 57.80, "hsgd_gm": 79.84, "al2gd_pm": 71.32,
               "permfl_gm": 79.15, "permfl_pm": 98.67},
    "emnist10": {"fedavg_gm": 92.73, "perfedavg_pm": 97.37,
                 "pfedme_pm": 97.18, "ditto_gm": 90.58, "hsgd_gm": 96.03,
                 "al2gd_pm": 92.94, "permfl_gm": 93.12, "permfl_pm": 98.79},
}


def model_for(dataset: str, convex: bool):
    if dataset == "synthetic":
        cfg = MCLR if convex else DNN
        if convex:
            cfg = dataclasses.replace(cfg, input_shape=(60,))
        return cfg
    return MCLR if convex else CNN


def make_fed_data(dataset: str, seed: int = 0, *, m=M_TEAMS, n=N_DEVICES,
                  samples_per_device: int = 48, strategy: str = "random"):
    rng = np.random.default_rng(seed)
    if dataset == "synthetic":
        devs = synthetic_tabular(rng, m * n, min_samples=samples_per_device,
                                 max_samples=samples_per_device * 8)
        return partition_tabular(devs, m_teams=m, n_devices=n,
                                 samples_per_device=samples_per_device)
    x, y = make_dataset(dataset, rng, n_per_class=40 * n)
    return partition_label_skew(rng, x, y, m_teams=m, n_devices=n,
                                classes_per_device=2,
                                samples_per_device=samples_per_device,
                                strategy=strategy)


def fns_for(cfg):
    loss = lambda p, b: PM.loss_fn(p, cfg, b)
    met = lambda p, b: PM.accuracy(p, cfg, b)
    return loss, met


def make_algorithm(name: str, loss, *, hp=HP_DEFAULT, lr: float = 0.03,
                   comm=None):
    """Paper-default FLAlgorithm instances for the unified engine, keyed by
    the Table-1 names. lr is the baselines' device learning rate."""
    builders = {
        "permfl": lambda: PerMFL(loss, hp, comm=comm),
        "fedavg": lambda: B.FedAvg(loss, lr=lr,
                                   local_steps=hp.k_team * hp.l_local),
        "perfedavg": lambda: B.PerFedAvg(loss, lr=lr, inner_lr=lr,
                                         local_steps=20),
        "pfedme": lambda: B.PFedMe(loss, lr=1.0, inner_lr=lr, lam=15.0,
                                   inner_steps=10, local_rounds=5),
        "ditto": lambda: B.Ditto(loss, lr=lr, lam=0.5, local_steps=20),
        "hsgd": lambda: B.HSGD(loss, lr=lr, k_team=hp.k_team,
                               l_local=hp.l_local),
        "l2gd": lambda: B.L2GD(loss, lr=lr, lam_c=0.5, lam_g=0.5,
                               k_team=hp.k_team, l_local=hp.l_local),
    }
    return builders[name]()


def to_jax(fd):
    tr = {"x": jnp.asarray(fd.train_x), "y": jnp.asarray(fd.train_y)}
    va = {"x": jnp.asarray(fd.val_x), "y": jnp.asarray(fd.val_y)}
    return tr, va


def init_model(cfg, seed: int = 0):
    return PM.init_params(jax.random.PRNGKey(seed), cfg)
