"""Summarize bench_output.txt table1 lines into the EXPERIMENTS.md §Repro
markdown table (ours vs the paper's A100 numbers, qualitative).

The paper column comes from the scenario layer's single source of truth
(`repro.scenarios.paper_refs.table1_ref`), not from whatever the CSV
happened to carry."""
from __future__ import annotations

import os
import sys
from collections import defaultdict

HERE = os.path.dirname(os.path.abspath(__file__))
BENCH = os.path.join(HERE, "..", "bench_output.txt")
sys.path.insert(0, os.path.join(HERE, "..", "src"))

from repro.scenarios.paper_refs import table1_ref  # noqa: E402

SHOW = ["fedavg_gm", "perfedavg_pm", "pfedme_pm", "ditto_pm", "hsgd_gm",
        "l2gd_pm", "permfl_gm", "permfl_pm"]


def gen():
    rows = defaultdict(dict)   # (dataset, model) -> {algo: (ours, paper)}
    for line in open(BENCH):
        if not line.startswith("table1,"):
            continue
        _, ds, mdl, algo, acc, _ = line.strip().split(",")
        paper = table1_ref(ds, convex=(mdl == "mclr"), key=algo)
        rows[(ds, mdl)][algo] = (float(acc), paper if paper is not None
                                 else "")
    out = ["### Table-1 analogue (ours, quick scale / paper A100 values)\n"]
    out.append("| dataset | model | " + " | ".join(SHOW) + " |")
    out.append("|---" * (len(SHOW) + 2) + "|")
    for (ds, mdl), algos in sorted(rows.items()):
        cells = []
        for a in SHOW:
            ours, paper = algos.get(a, (float("nan"), ""))
            cells.append(f"{100 * ours:.1f}" + (f" / {paper}" if paper
                                                else ""))
        out.append(f"| {ds} | {mdl} | " + " | ".join(cells) + " |")
    out.append("\nCells are `ours(%) / paper(%)`. Data here is the offline "
               "synthetic re-materialization at reduced rounds — compare "
               "orderings (PerMFL PM >= its GM and >= FedAvg GM in every "
               "row), not magnitudes.")
    return "\n".join(out)


if __name__ == "__main__":
    md = gen()
    exp_path = os.path.join(HERE, "..", "EXPERIMENTS.md")
    exp = open(exp_path).read()
    if "<!-- REPRO-TABLE -->" in exp:
        exp = exp.replace("<!-- REPRO-TABLE -->", md)
        open(exp_path, "w").write(exp)
        print("spliced into EXPERIMENTS.md")
    else:
        print(md)
