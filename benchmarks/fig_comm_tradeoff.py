"""Accuracy-vs-megabytes tradeoff for the tiered comm subsystem.

Sweeps compressor x level on the paper's MNIST/MCLR setting and reports,
per config, the final personalized accuracy against total bytes moved
(per tier, from the CommLedger). Reproduction targets: (a) identity
compression is accuracy-neutral; (b) top-10% with error feedback stays
within 2 points of uncompressed PM accuracy while cutting uplink bytes
>4x; (c) every lossy compressor moves fewer uplink bytes than identity.
"""
from __future__ import annotations

from repro.comm import CommConfig
from repro.train import fl_trainer as FT

from benchmarks.fl_common import (HP_DEFAULT, fns_for, init_model,
                                  make_fed_data, model_for, to_jax)

SWEEP = [
    ("identity", CommConfig("identity")),
    ("topk_10", CommConfig("topk", k_frac=0.1)),
    ("topk_25", CommConfig("topk", k_frac=0.25)),
    ("randk_10", CommConfig("randk", k_frac=0.1)),
    ("int8", CommConfig("int8")),
    ("sign", CommConfig("sign")),
]


def main(quick=True, csv=print):
    rounds = 8 if quick else 40
    cfg_model = model_for("mnist", True)
    fd = make_fed_data("mnist", seed=6)
    tr, va = to_jax(fd)
    loss, met = fns_for(cfg_model)
    p0 = init_model(cfg_model)
    m, n = fd.m_teams, fd.n_devices

    base = FT.run_permfl(p0, tr, va, loss_fn=loss, metric_fn=met,
                         hp=HP_DEFAULT, rounds=rounds, m=m, n=n)
    csv(f"fig_comm,mnist,mclr,uncompressed,pm,,{base.pm_acc[-1]:.4f}")

    results = {}
    for name, ccfg in SWEEP:
        r = FT.run_permfl(p0, tr, va, loss_fn=loss, metric_fn=met,
                          hp=HP_DEFAULT, rounds=rounds, m=m, n=n, comm=ccfg)
        results[name] = r
        t = r.comm.totals()
        mb = t.total / 1e6
        csv(f"fig_comm,mnist,mclr,{name},pm,,{r.pm_acc[-1]:.4f}")
        csv(f"fig_comm,mnist,mclr,{name},mb_total,,{mb:.2f}")
        csv(f"fig_comm,mnist,mclr,{name},bytes,wan_up,{t.wan_up}")
        csv(f"fig_comm,mnist,mclr,{name},bytes,wan_down,{t.wan_down}")
        csv(f"fig_comm,mnist,mclr,{name},bytes,lan_up,{t.lan_up}")
        csv(f"fig_comm,mnist,mclr,{name},bytes,lan_down,{t.lan_down}")
        csv(f"fig_comm,mnist,mclr,{name},uplink_ratio,,"
            f"{r.comm.summary()['uplink_ratio']:.1f}")

    failures = []
    ident = results["identity"]
    if abs(ident.pm_acc[-1] - base.pm_acc[-1]) > 0.01:
        failures.append("fig_comm: identity compression changed PM accuracy")
    if results["topk_10"].pm_acc[-1] < ident.pm_acc[-1] - 0.02:
        failures.append("fig_comm: topk(0.1)+EF not within 2 points of "
                        "uncompressed")
    id_up = ident.comm.totals().wan_up + ident.comm.totals().lan_up
    for name, r in results.items():
        if name == "identity":
            continue
        up = r.comm.totals().wan_up + r.comm.totals().lan_up
        if not up < id_up:
            failures.append(f"fig_comm: {name} uplink not below identity")
    return failures


if __name__ == "__main__":
    main()
