"""Accuracy-vs-megabytes tradeoff for the tiered comm subsystem.

Sweeps compressor x level on the paper's MNIST/MCLR setting and reports,
per config, the final personalized accuracy against total bytes moved
(per tier, from the CommLedger). Each configuration is the registered
scenario ``comm/mnist/mclr/{name}`` (the CommConfig lives in the spec).
Reproduction targets: (a) identity compression is accuracy-neutral;
(b) top-10% with error feedback stays within 2 points of uncompressed PM
accuracy while cutting uplink bytes >4x; (c) every lossy compressor
moves fewer uplink bytes than identity.
"""
from __future__ import annotations

from repro.scenarios import SCENARIOS, run_scenario

COMPRESSORS = ("identity", "topk_10", "topk_25", "randk_10", "int8", "sign")


def main(quick=True, csv=print):
    rounds = 8 if quick else 40

    base = run_scenario(SCENARIOS["comm/mnist/mclr/uncompressed"],
                        rounds=rounds)
    csv(f"fig_comm,mnist,mclr,uncompressed,pm,,{base.pm_acc[-1]:.4f}")

    results = {}
    for name in COMPRESSORS:
        r = run_scenario(SCENARIOS[f"comm/mnist/mclr/{name}"],
                         rounds=rounds)
        results[name] = r
        t = r.comm.totals()
        mb = t.total / 1e6
        csv(f"fig_comm,mnist,mclr,{name},pm,,{r.pm_acc[-1]:.4f}")
        csv(f"fig_comm,mnist,mclr,{name},mb_total,,{mb:.2f}")
        csv(f"fig_comm,mnist,mclr,{name},bytes,wan_up,{t.wan_up}")
        csv(f"fig_comm,mnist,mclr,{name},bytes,wan_down,{t.wan_down}")
        csv(f"fig_comm,mnist,mclr,{name},bytes,lan_up,{t.lan_up}")
        csv(f"fig_comm,mnist,mclr,{name},bytes,lan_down,{t.lan_down}")
        csv(f"fig_comm,mnist,mclr,{name},uplink_ratio,,"
            f"{r.comm.summary()['uplink_ratio']:.1f}")

    failures = []
    ident = results["identity"]
    if abs(ident.pm_acc[-1] - base.pm_acc[-1]) > 0.01:
        failures.append("fig_comm: identity compression changed PM accuracy")
    if results["topk_10"].pm_acc[-1] < ident.pm_acc[-1] - 0.02:
        failures.append("fig_comm: topk(0.1)+EF not within 2 points of "
                        "uncompressed")
    id_up = ident.comm.totals().wan_up + ident.comm.totals().lan_up
    for name, r in results.items():
        if name == "identity":
            continue
        up = r.comm.totals().wan_up + r.comm.totals().lan_up
        if not up < id_up:
            failures.append(f"fig_comm: {name} uplink not below identity")
    return failures


if __name__ == "__main__":
    main()
